"""The FT THEOREM on the simulation runtime: for HPCG / CloverLeaf / PIC,
any survivable failure schedule produces bitwise the SAME result as the
failure-free run — under checkpoint, replication, and combined modes.
This is the paper's §7 correctness claim, tested end-to-end with real
numerics (kills, promotions, drains, replays, restores)."""
import tempfile

import numpy as np
import pytest

from repro.apps.cloverleaf import CloverLeaf
from repro.apps.hpcg import HPCG
from repro.apps.pic import PIC
from repro.configs.base import FTConfig
from repro.core.failure_sim import FailureEvent, WeibullInjector
from repro.simrt import CostModel, SimRuntime

APPS = {
    "hpcg": (HPCG, dict(nx=8, ny=8, nz=4)),
    "cloverleaf": (CloverLeaf, dict(nx=16, ny_local=8)),
    "pic": (PIC, dict(cells_per_rank=32, particles_per_rank=96)),
}


def run(app_cls, kw, mode, events=(), steps=12, n=4, rep=1.0,
        respawn=True, wpn=2):
    app = app_cls(n_ranks=n, **kw)
    ft = FTConfig(mode=mode, replication_degree=rep, mtbf_s=1e9,
                  ckpt_interval_s=4.0)
    with tempfile.TemporaryDirectory() as d:
        rt = SimRuntime(app, ft, costs=CostModel(step_time_s=1.0,
                                                 ckpt_cost_s=0.2,
                                                 restore_cost_s=0.3),
                        ckpt_dir=d, failure_events=list(events),
                        workers_per_node=wpn, respawn_on_restart=respawn)
        return rt.run(steps)


@pytest.fixture(scope="module")
def baselines():
    return {name: run(cls, kw, "none").check_value
            for name, (cls, kw) in APPS.items()}


@pytest.mark.parametrize("name", list(APPS))
def test_replication_promotion_exact(name, baselines):
    cls, kw = APPS[name]
    ev = [FailureEvent(2.5, (0,)), FailureEvent(5.5, (2,)),
          FailureEvent(8.5, (1,))]
    r = run(cls, kw, "replication", ev)
    assert r.promotions == 3 and r.restarts == 0
    assert r.check_value == pytest.approx(baselines[name], abs=0)


@pytest.mark.parametrize("name", list(APPS))
def test_checkpoint_restart_exact(name, baselines):
    cls, kw = APPS[name]
    ev = [FailureEvent(6.5, (2,))]
    r = run(cls, kw, "checkpoint", ev, rep=0.0)
    assert r.restarts == 1 and r.time.rollback > 0
    assert r.check_value == pytest.approx(baselines[name], abs=0)


@pytest.mark.parametrize("name", list(APPS))
def test_combined_pair_death_exact(name, baselines):
    cls, kw = APPS[name]
    ev = [FailureEvent(3.2, (1,)), FailureEvent(6.3, (5,))]  # kill rank1 twice
    r = run(cls, kw, "combined", ev)
    assert r.restarts == 1 and r.promotions >= 1
    assert r.check_value == pytest.approx(baselines[name], abs=0)


def test_node_failure_kills_worker_group(baselines):
    cls, kw = APPS["hpcg"]
    # workers_per_node=2: node 0 = workers {0,1} -> two promotions at once
    ev = [FailureEvent(4.5, (0, 1))]
    r = run(cls, kw, "replication", ev)
    assert r.promotions == 2
    assert r.check_value == pytest.approx(baselines["hpcg"], abs=0)


def test_partial_replication_mixed_recovery(baselines):
    cls, kw = APPS["hpcg"]
    # rep degree 0.5: ranks 0,1 replicated (workers 4,5). Kill a replicated
    # cmp (promote) then an unreplicated cmp (restart).
    ev = [FailureEvent(2.5, (1,)), FailureEvent(6.5, (3,))]
    r = run(cls, kw, "combined", ev, rep=0.5)
    assert r.promotions == 1 and r.restarts == 1
    assert r.check_value == pytest.approx(baselines["hpcg"], abs=0)


def test_elastic_restart_without_respawn(baselines):
    """After a pair death without spare workers, the job restarts with
    fewer workers and a lower replication degree (paper §3.3)."""
    cls, kw = APPS["hpcg"]
    ev = [FailureEvent(3.2, (1,)), FailureEvent(5.3, (5,))]
    r = run(cls, kw, "combined", ev, respawn=False)
    assert r.restarts == 1
    assert r.check_value == pytest.approx(baselines["hpcg"], abs=0)


def test_weibull_schedule_replication_survives(baselines):
    """Random Weibull kills at a high rate; full replication + checkpoints
    keep the answer exact."""
    cls, kw = APPS["pic"]
    inj = WeibullInjector(mtbf_s=4.0, shape=0.7, seed=5)
    ev = inj.schedule(12.0, alive_workers=range(8))
    r = run(cls, kw, "combined", ev)
    assert r.failures >= 1
    assert r.check_value == pytest.approx(baselines["pic"], abs=0)


def test_message_replay_happens(baselines):
    """Promotions mid-step drop in-flight messages; sender logs must replay
    them (replays counter > 0) and the answer stays exact."""
    cls, kw = APPS["pic"]
    ev = [FailureEvent(2.5, (0,)), FailureEvent(5.5, (2,))]
    r = run(cls, kw, "replication", ev)
    assert r.replays > 0
    assert r.check_value == pytest.approx(baselines["pic"], abs=0)


def test_efficiency_accounting():
    cls, kw = APPS["hpcg"]
    r = run(cls, kw, "checkpoint", [FailureEvent(6.5, (2,))], rep=0.0)
    t = r.time
    assert t.total == pytest.approx(
        t.useful + t.redundant + t.ckpt_write + t.restore + t.rollback
        + t.repair + t.log_removal)
    assert r.efficiency < 1.0
