# Repo checks. `make test` is the tier-1 command from ROADMAP.md.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint analyze mypy check bench bench-smoke bench-store \
    bench-topo bench-clock bench-scale bench-obs bench-pool \
    bench-collective profile

test:
	$(PY) -m pytest -x -q

# lint: syntax/bytecode check everywhere (no external linter is baked into
# the container); flake8 runs additionally when available.
lint:
	$(PY) -m compileall -q src tests examples benchmarks
	@$(PY) -c "import flake8" 2>/dev/null \
	    && $(PY) -m flake8 --max-line-length 100 src tests \
	    || echo "flake8 not installed; compileall-only lint"

# repro.analyze: determinism/FT lint over src/repro + static schedule
# verification of the three paper apps (docs/analyze_api.md). Numpy-only.
analyze:
	$(PY) -m repro.analyze

# mypy over the typed core packages (mypy.ini un-ignores repro.clock,
# repro.topo, repro.analyze); skipped where mypy isn't installed.
mypy:
	@$(PY) -c "import mypy" 2>/dev/null \
	    && $(PY) -m mypy --config-file mypy.ini src/repro \
	    || echo "mypy not installed; skipping type check"

check: lint analyze mypy test

# -m so the benchmarks package resolves from the repo root
bench:
	$(PY) -m benchmarks.run

# the cheap failure-pipeline subset CI runs on every push
bench-smoke:
	$(PY) -m benchmarks.run --only fig13_log_replay --only fig9_time_distribution --only fig14_memstore --only fig15_topology --only fig16_taskpool --only clock_breakdown

# the disk-vs-memory checkpoint backend comparison (repro.store)
bench-store:
	$(PY) -m benchmarks.run --only fig14_memstore

# topology-priced collectives: dense vs tree/ring + per-topology crossover
bench-topo:
	$(PY) -m benchmarks.run --only fig15_topology

# the unified-clock TimeBreakdown across FTSession + SimRuntime (repro.clock)
bench-clock:
	$(PY) -m benchmarks.run --only clock_breakdown

# simulator-core throughput ladder N=8192->131072 (docs/perf.md); writes
# BENCH_scale.json. CI runs `--smoke --no-write` (N<=4096 floor check +
# the obs-on overhead gate).
bench-scale:
	$(PY) -m benchmarks.bench_scale

# elastic task-pool goodput under failures (repro.pool, docs/pool_api.md):
# goodput + p99 latency vs MTTI x FT configuration, numpy-only
bench-pool:
	$(PY) -m benchmarks.run --only fig16_taskpool

# observability smoke (docs/obs_api.md): traced HPCG@64 with a mid-run
# node kill; asserts the trace/metrics artifacts parse, the recovery
# arcs are present, and band bytes reconcile with the sender logs
bench-obs:
	$(PY) -m benchmarks.obs_smoke

# switchboard-collective throughput ladder (docs/perf.md, SoA tables);
# writes BENCH_collective.json. CI runs `--smoke --no-write` (N<=4096
# steps/s floor)
bench-collective:
	$(PY) -m benchmarks.bench_collective

# cProfile over the bench-scale smoke point, top-25 cumulative — the
# reproducible backing for hot-path claims in docs/perf.md
profile:
	$(PY) -m benchmarks.profile_hotpath
